//! Interior-point normal equations (Sec. 6.2): C = A·D²·Aᵀ with a
//! constraint matrix whose structure is fixed across iterations, so the
//! hypergraph partitioning cost can be amortized. Demonstrates the
//! paper's LP finding: outer-product ≈ fine-grained, row-wise far worse.
//!
//! ```bash
//! cargo run --release --offline --example lp_normal_equations
//! ```

use spgemm_hp::gen::lp::{ipm_scaling, lp_constraints, LpParams};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{self, partition, PartitionerConfig};
use spgemm_hp::planner::{PlanOutcome, Planner};
use spgemm_hp::sparse::ops;
use spgemm_hp::util::Rng;
use spgemm_hp::{cost, sim, sparse};

fn main() -> spgemm_hp::Result<()> {
    let mut rng = Rng::new(7);
    let params = LpParams::pds_like(1200, 4000);
    let a = lp_constraints(&params, &mut rng)?;
    println!("LP constraint matrix: {}x{} ({} nnz)", a.nrows, a.ncols, a.nnz());

    // three interior-point iterations: D changes, S_A does not — partition
    // once on the structure, reuse every iteration
    let kinds = [
        ModelKind::FineGrained,
        ModelKind::OuterProduct,
        ModelKind::MonoA,
        ModelKind::RowWise,
        ModelKind::MonoC,
    ];
    let p = 16;
    // partition ONCE per model using the first iterate's structure
    let d2 = ipm_scaling(a.ncols, &mut rng);
    let b0 = ops::scale_rows(&a.transpose(), &d2)?;
    println!("\npartitioning once (structure is iteration-invariant), p = {p}:");
    println!("{:<16} {:>12} {:>12} {:>10}", "model", "comm_max", "volume", "part_ms");
    for kind in kinds {
        let model = build_model(&a, &b0, kind, false)?;
        let t = std::time::Instant::now();
        let cfg = PartitionerConfig {
            epsilon: 0.03,
            threads: partition::default_threads(),
            ..PartitionerConfig::new(p)
        };
        let prt = partition(&model.h, &cfg)?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let m = cost::evaluate(&model.h, &prt, p)?;
        println!(
            "{:<16} {:>12} {:>12} {:>10.1}",
            kind.name(),
            m.comm_max,
            m.connectivity_volume,
            ms
        );
    }

    // subsequent iterations reuse the *whole plan*: the planner caches
    // by structural fingerprint, and A·(D²Aᵀ)'s structure is
    // iteration-invariant, so every iteration after the first hits —
    // only the O(plan size) value rebind is paid per iterate
    println!("\nreusing the outer-product plan across 3 IPM iterations via the planner:");
    let mut planner = Planner::in_memory();
    let pcfg = PartitionerConfig {
        epsilon: 0.03,
        threads: partition::default_threads(),
        ..PartitionerConfig::new(p)
    };
    let cold = planner.plan_or_build(&a, &b0, ModelKind::OuterProduct, &pcfg, 8)?;
    println!("  inspect: {} in {:.1} ms", cold.outcome.name(), cold.plan_ns as f64 / 1e6);
    for it in 0..3 {
        let d2 = ipm_scaling(a.ncols, &mut rng);
        let b = ops::scale_rows(&a.transpose(), &d2)?;
        let planned = planner.plan_or_build(&a, &b, ModelKind::OuterProduct, &pcfg, 8)?;
        assert_eq!(planned.outcome, PlanOutcome::Hit, "structure is iteration-invariant");
        let (_, c) = sim::simulate(&a, &b, &planned.alg)?;
        assert!(c.approx_eq(&sparse::spgemm(&a, &b)?, 1e-9));
        println!(
            "  iter {it}: plan {} in {:.1} ms; C has {} nnz; comm_max {} (unchanged)",
            planned.outcome.name(),
            planned.plan_ns as f64 / 1e6,
            c.nnz(),
            planned.comm_max
        );
    }
    println!("\npaper's conclusion (Sec. 6.2): outer-product tracks fine-grained;");
    println!("row-wise/monochrome-C can be an order of magnitude worse.");
    Ok(())
}
