//! Interior-point normal equations (Sec. 6.2): C = A·D²·Aᵀ with a
//! constraint matrix whose structure is fixed across iterations, so the
//! hypergraph partitioning cost can be amortized. Demonstrates the
//! paper's LP finding: outer-product ≈ fine-grained, row-wise far worse.
//!
//! ```bash
//! cargo run --release --offline --example lp_normal_equations
//! ```

use spgemm_hp::gen::lp::{ipm_scaling, lp_constraints, LpParams};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, PartitionerConfig};
use spgemm_hp::sparse::ops;
use spgemm_hp::util::Rng;
use spgemm_hp::{cost, sparse};

fn main() -> spgemm_hp::Result<()> {
    let mut rng = Rng::new(7);
    let params = LpParams::pds_like(1200, 4000);
    let a = lp_constraints(&params, &mut rng)?;
    println!("LP constraint matrix: {}x{} ({} nnz)", a.nrows, a.ncols, a.nnz());

    // three interior-point iterations: D changes, S_A does not — partition
    // once on the structure, reuse every iteration
    let kinds = [
        ModelKind::FineGrained,
        ModelKind::OuterProduct,
        ModelKind::MonoA,
        ModelKind::RowWise,
        ModelKind::MonoC,
    ];
    let p = 16;
    // partition ONCE per model using the first iterate's structure
    let d2 = ipm_scaling(a.ncols, &mut rng);
    let b0 = ops::scale_rows(&a.transpose(), &d2)?;
    println!("\npartitioning once (structure is iteration-invariant), p = {p}:");
    println!("{:<16} {:>12} {:>12} {:>10}", "model", "comm_max", "volume", "part_ms");
    let mut partitions = Vec::new();
    for kind in kinds {
        let model = build_model(&a, &b0, kind, false)?;
        let t = std::time::Instant::now();
        let cfg = PartitionerConfig { epsilon: 0.03, ..PartitionerConfig::new(p) };
        let prt = partition(&model.h, &cfg)?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let m = cost::evaluate(&model.h, &prt, p)?;
        println!(
            "{:<16} {:>12} {:>12} {:>10.1}",
            kind.name(),
            m.comm_max,
            m.connectivity_volume,
            ms
        );
        partitions.push((kind, model, prt));
    }

    // subsequent iterations reuse the partition: structure identical, so
    // the modeled communication is identical — only values change
    println!("\nreusing partitions across 3 IPM iterations (values change, structure doesn't):");
    for it in 0..3 {
        let d2 = ipm_scaling(a.ncols, &mut rng);
        let b = ops::scale_rows(&a.transpose(), &d2)?;
        let c = sparse::spgemm(&a, &b)?;
        // communication cost is structure-only: recomputing it confirms
        let (kind, model, prt) = &partitions[1]; // outer-product
        let m = cost::evaluate(&model.h, prt, p)?;
        println!(
            "  iter {it}: C has {} nnz; {} comm_max (unchanged) [{}]",
            c.nnz(),
            m.comm_max,
            kind.name()
        );
    }
    println!("\npaper's conclusion (Sec. 6.2): outer-product tracks fine-grained;");
    println!("row-wise/monochrome-C can be an order of magnitude worse.");
    Ok(())
}
