//! Markov clustering (Sec. 6.3): the full MCL iteration — expand
//! (A ← A²), inflate (entrywise power + column normalize), prune — with
//! the expansion SpGEMM parallelized via hypergraph partitioning.
//!
//! ```bash
//! cargo run --release --offline --example markov_clustering
//! ```

use spgemm_hp::gen::{rmat, RmatParams};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, PartitionerConfig};
use spgemm_hp::sparse::{ops, Coo, Csr};
use spgemm_hp::util::Rng;
use spgemm_hp::{cost, sparse};

/// Column-normalize (make each column a probability distribution).
fn normalize_columns(m: &Csr) -> Csr {
    let mut col_sums = vec![0f64; m.ncols];
    for (_, j, v) in m.iter() {
        col_sums[j as usize] += v;
    }
    let mut out = m.clone();
    for p in 0..out.values.len() {
        let s = col_sums[out.colind[p] as usize];
        if s != 0.0 {
            out.values[p] /= s;
        }
    }
    out
}

/// Inflation: entrywise power `r`, then column normalize.
fn inflate(m: &Csr, r: f64) -> Csr {
    let mut out = m.clone();
    for v in &mut out.values {
        *v = v.powf(r);
    }
    normalize_columns(&out)
}

fn main() -> spgemm_hp::Result<()> {
    let mut rng = Rng::new(11);
    let adj = rmat(&RmatParams::protein(9, 6.0), &mut rng)?;
    let mut m = normalize_columns(&adj);
    println!("MCL on a {}x{} graph ({} nnz)", m.nrows, m.ncols, m.nnz());

    // --- partition the first expansion (the representative SpGEMM) -----
    let p = 16;
    println!("\npartitioning the expansion A² for p = {p}:");
    println!("{:<16} {:>12} {:>12}", "model", "comm_max", "volume");
    let mut best: Option<(&str, u64)> = None;
    let mut worst_1d: u64 = 0;
    for kind in [
        ModelKind::FineGrained,
        ModelKind::RowWise,
        ModelKind::OuterProduct,
        ModelKind::MonoA,
        ModelKind::MonoC,
    ] {
        let model = build_model(&m, &m, kind, false)?;
        let cfg = PartitionerConfig {
            epsilon: 0.10,
            threads: spgemm_hp::partition::default_threads(),
            ..PartitionerConfig::new(p)
        };
        let prt = partition(&model.h, &cfg)?;
        let metrics = cost::evaluate(&model.h, &prt, p)?;
        println!(
            "{:<16} {:>12} {:>12}",
            kind.name(),
            metrics.comm_max,
            metrics.connectivity_volume
        );
        if matches!(kind, ModelKind::RowWise) {
            worst_1d = worst_1d.max(metrics.comm_max);
        }
        if best.map(|(_, c)| metrics.comm_max < c).unwrap_or(true) {
            best = Some((kind.name(), metrics.comm_max));
        }
    }
    let (best_name, best_cost) = best.unwrap();
    println!(
        "\nbest model: {best_name} ({best_cost} words); row-wise needs {:.1}x more",
        worst_1d as f64 / best_cost.max(1) as f64
    );

    // --- run actual MCL iterations --------------------------------------
    println!("\nrunning 4 MCL iterations (expand → inflate → prune):");
    for it in 0..4 {
        let squared = sparse::spgemm(&m, &m)?;
        let inflated = inflate(&squared, 2.0);
        m = ops::prune(&inflated, 1e-4, false);
        println!("  iter {}: nnz {} -> {} after prune", it + 1, squared.nnz(), m.nnz());
    }
    // interpret clusters: attractors are rows with a diagonal-dominant entry
    let mut attractors = 0;
    for i in 0..m.nrows {
        if m.row_iter(i).any(|(j, v)| j as usize == i && v > 0.5) {
            attractors += 1;
        }
    }
    println!("\nconverging toward {attractors} attractor rows (cluster seeds)");

    // a cluster assignment sketch: each column joins its max-entry row
    let mut cluster_of = vec![usize::MAX; m.ncols];
    let t = m.transpose();
    for j in 0..t.nrows {
        if let Some((i, _)) = t
            .row_iter(j)
            .max_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
        {
            cluster_of[j] = i as usize;
        }
    }
    let mut distinct: Vec<usize> =
        cluster_of.iter().copied().filter(|&c| c != usize::MAX).collect();
    distinct.sort_unstable();
    distinct.dedup();
    println!("{} clusters identified", distinct.len());
    let _ = Coo::new(1, 1); // keep example self-contained in imports
    Ok(())
}
