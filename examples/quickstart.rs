//! Quickstart: build an SpGEMM instance, construct every hypergraph
//! model, partition each, and compare the modeled communication costs.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use spgemm_hp::gen::{rmat, RmatParams};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, PartitionerConfig};
use spgemm_hp::util::Rng;
use spgemm_hp::{cost, sparse};

fn main() -> spgemm_hp::Result<()> {
    // 1. An input: a small scale-free graph, squared (the MCL pattern).
    let mut rng = Rng::new(42);
    let a = rmat(&RmatParams::social(9, 8.0), &mut rng)?;
    let b = a.clone();
    println!(
        "A: {}x{} with {} nonzeros; computing C = A² ({} multiplications)",
        a.nrows,
        a.ncols,
        a.nnz(),
        sparse::spgemm_flops(&a, &b)?
    );

    // 2. Build each parallelization model and partition it for p = 8.
    let p = 8;
    println!(
        "\n{:<16} {:>10} {:>10} {:>12} {:>10}",
        "model",
        "vertices",
        "nets",
        "comm_max",
        "volume"
    );
    for kind in ModelKind::ALL {
        let model = build_model(&a, &b, kind, false)?;
        let cfg = PartitionerConfig { epsilon: 0.03, ..PartitionerConfig::new(p) };
        let part = partition(&model.h, &cfg)?;
        let m = cost::evaluate(&model.h, &part, p)?;
        println!(
            "{:<16} {:>10} {:>10} {:>12} {:>10}",
            kind.name(),
            model.h.num_vertices(),
            model.h.num_nets(),
            m.comm_max,
            m.connectivity_volume
        );
    }
    println!("\ncomm_max is the critical-path bandwidth lower bound of Lem. 4.2 —");
    println!("the quantity Figs. 7–9 of the paper plot. Lower is better.");
    Ok(())
}
