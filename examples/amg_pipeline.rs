//! Algebraic-multigrid setup pipeline (Sec. 6.1): build a two-level grid
//! hierarchy with the paper's model problem, run both SpGEMMs of the
//! Galerkin triple product, and compare hypergraph-partitioned algorithms
//! against the geometric baselines available on the regular grid.
//!
//! ```bash
//! cargo run --release --offline --example amg_pipeline -- [n] [p]
//! ```

use spgemm_hp::gen::{smoothed_aggregation_prolongator, stencil27, Grid3};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{self, partition, PartitionerConfig};
use spgemm_hp::planner::{PlanOutcome, Planner};
use spgemm_hp::{cost, repro, sparse};

fn main() -> spgemm_hp::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let p: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    // --- build the hierarchy (eq. (6)) ---------------------------------
    let a1 = stencil27(n);
    let p1 = smoothed_aggregation_prolongator(&a1, n)?;
    let (ap, a2) = sparse::triple_product(&a1, &p1)?;
    println!("AMG setup: A1 is {0}x{0} ({1} nnz)", a1.nrows, a1.nnz());
    println!("           P1 is {}x{} ({} nnz)", p1.nrows, p1.ncols, p1.nnz());
    println!("           A2 = P1ᵀ·A1·P1 is {0}x{0} ({1} nnz)", a2.nrows, a2.nnz());

    // --- SpGEMM 1: A·P ----------------------------------------------------
    println!("\n--- SpGEMM 1: A·P on p={p} ---");
    println!("{:<18} {:>12} {:>12} {:>8}", "model", "comm_max", "volume", "imbal");
    for kind in
        [ModelKind::FineGrained, ModelKind::RowWise, ModelKind::OuterProduct, ModelKind::ColWise]
    {
        let model = build_model(&a1, &p1, kind, false)?;
        let cfg = PartitionerConfig {
            epsilon: 0.03,
            threads: partition::default_threads(),
            ..PartitionerConfig::new(p)
        };
        let prt = partition(&model.h, &cfg)?;
        let m = cost::evaluate(&model.h, &prt, p)?;
        println!(
            "{:<18} {:>12} {:>12} {:>8.3}",
            kind.name(),
            m.comm_max,
            m.connectivity_volume,
            m.comp_imbalance()
        );
    }
    // geometric baseline on the regular grid (paper's "Geometric-row")
    if let Ok(gpart) = Grid3::new(n).subcube_partition(p) {
        let row = repro::measure_given_partition(
            "amg",
            "AP",
            &a1,
            &p1,
            ModelKind::RowWise,
            "geometric-row",
            &gpart,
            p,
        )?;
        println!(
            "{:<18} {:>12} {:>12} {:>8.3}",
            row.model,
            row.comm_max,
            row.volume,
            row.comp_imbalance
        );
    }

    // --- SpGEMM 2: Pᵀ·(AP) --------------------------------------------------
    let pt = p1.transpose();
    println!("\n--- SpGEMM 2: Pᵀ·(AP) on p={p} ---");
    println!("{:<18} {:>12} {:>12} {:>8}", "model", "comm_max", "volume", "imbal");
    for kind in
        [ModelKind::FineGrained, ModelKind::RowWise, ModelKind::OuterProduct, ModelKind::MonoA]
    {
        let model = build_model(&pt, &ap, kind, false)?;
        let cfg = PartitionerConfig {
            epsilon: 0.03,
            threads: partition::default_threads(),
            ..PartitionerConfig::new(p)
        };
        let prt = partition(&model.h, &cfg)?;
        let m = cost::evaluate(&model.h, &prt, p)?;
        println!(
            "{:<18} {:>12} {:>12} {:>8.3}",
            kind.name(),
            m.comm_max,
            m.connectivity_volume,
            m.comp_imbalance()
        );
    }
    if let Ok(gpart) = Grid3::new(n).subcube_partition(p) {
        let row = repro::measure_given_partition(
            "amg",
            "PTAP",
            &pt,
            &ap,
            ModelKind::OuterProduct,
            "geometric-outer",
            &gpart,
            p,
        )?;
        println!(
            "{:<18} {:>12} {:>12} {:>8.3}",
            row.model,
            row.comm_max,
            row.volume,
            row.comp_imbalance
        );
    }

    // --- plan amortization across repeated setups ------------------------
    // AMG setup recurs on the same mesh (time-dependent or parameterized
    // problems rebuild the hierarchy with identical structure), so the
    // inspector-executor planner caches both SpGEMMs' full execution
    // plans and serves later setups warm.
    println!("\n--- plan caching across 2 AMG setup rounds (the inspector-executor win) ---");
    let mut planner = Planner::in_memory();
    println!("{:<10} {:<18} {:>6} {:>10}", "round", "spgemm", "plan", "plan_ms");
    for round in 0..2 {
        for (label, x, y, kind) in [
            ("A·P", &a1, &p1, ModelKind::RowWise),
            ("Pᵀ·(AP)", &pt, &ap, ModelKind::OuterProduct),
        ] {
            let cfg = PartitionerConfig {
                epsilon: 0.03,
                threads: partition::default_threads(),
                ..PartitionerConfig::new(p)
            };
            let planned = planner.plan_or_build(x, y, kind, &cfg, 8)?;
            if round > 0 {
                assert_eq!(planned.outcome, PlanOutcome::Hit, "{label} round 2 must hit");
            }
            println!(
                "{:<10} {:<18} {:>6} {:>10.1}",
                round + 1,
                label,
                planned.outcome.name(),
                planned.plan_ns as f64 / 1e6
            );
        }
    }

    println!("\npaper's conclusion (Sec. 6.1): row-wise suffices for A·P; outer-product");
    println!("(or its 2D refinements) is needed for Pᵀ(AP).");
    Ok(())
}
