//! END-TO-END driver: all three layers composed on a real workload.
//!
//! Pipeline (the paper's system, deployed):
//!   1. generate a scale-free MCL graph (Sec. 6.3 workload);
//!   2. plan through the inspector–executor `planner`: build the
//!      hypergraph model, partition with the multilevel partitioner (the
//!      paper's contribution), lower to a concrete algorithm, and cache
//!      the fingerprinted execution plan;
//!   3. execute the plan on the leader/worker coordinator — expand/fold
//!      message routing over threads, tile batches dispatched to the
//!      AOT-compiled JAX/Pallas kernel through PJRT (L1+L2), scalar
//!      fallback for open tile groups;
//!   4. validate numerics against the sequential reference SpGEMM and
//!      validate the realized communication against the hypergraph bound
//!      (Lem. 4.2) and the Lem. 4.3 simulator;
//!   5. square the graph AGAIN (the MCL iteration pattern): every model's
//!      plan is now a cache hit, demonstrating the planning amortization
//!      the planner exists for.
//!
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_distributed_spgemm
//! ```

use spgemm_hp::coordinator::{self, CoordinatorConfig};
use spgemm_hp::gen::{rmat, RmatParams};
use spgemm_hp::hypergraph::models::ModelKind;
use spgemm_hp::partition::{self, PartitionerConfig};
use spgemm_hp::planner::{PlanOutcome, Planner};
use spgemm_hp::util::{Rng, Timer};
use spgemm_hp::{sim, sparse};

fn main() -> spgemm_hp::Result<()> {
    let mut rng = Rng::new(20160711);
    let a = rmat(&RmatParams::social(10, 8.0), &mut rng)?;
    let b = a.clone();
    let flops = sparse::spgemm_flops(&a, &b)?;
    println!(
        "workload: squaring a scale-free graph, {}x{}, {} nnz, {} multiplications",
        a.nrows,
        a.ncols,
        a.nnz(),
        flops
    );
    let t = Timer::start();
    let c_ref = sparse::spgemm(&a, &b)?;
    println!("reference Gustavson SpGEMM: {} nnz in {:.1} ms\n", c_ref.nnz(), t.elapsed_ms());

    let p = 8;
    let artifacts = std::path::PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.txt").exists();
    if !have_artifacts {
        println!("NOTE: run `make artifacts` first for the PJRT path; using reference backend\n");
    }
    let mut planner = Planner::in_memory();
    let models = [
        ModelKind::RowWise,
        ModelKind::ColWise,
        ModelKind::OuterProduct,
        ModelKind::MonoA,
        ModelKind::MonoB,
        ModelKind::MonoC,
    ];

    let mut all_ok = true;
    for round in 0..2 {
        println!(
            "--- iteration {} ({}) ---",
            round + 1,
            if round == 0 { "cold plans" } else { "warm plans: the MCL A² reuse pattern" }
        );
        println!(
            "{:<16} {:>5} {:>8} {:>10} {:>10} {:>11} {:>10} {:>9} {:>8} {:>8} {:>6}",
            "model",
            "plan",
            "plan_ms",
            "bound_maxQ",
            "sim_words",
            "coord_words",
            "tile_mult",
            "scalar",
            "batches",
            "ms",
            "ok"
        );
        for kind in models {
            let cfg = PartitionerConfig {
                epsilon: 0.10,
                seed: 3,
                threads: partition::default_threads(),
                ..PartitionerConfig::new(p)
            };
            let planned = planner.plan_or_build(&a, &b, kind, &cfg, 8)?;
            // iteration 2 must be served entirely from the cache
            if round > 0 {
                assert_eq!(planned.outcome, PlanOutcome::Hit, "{kind:?} should hit");
            }
            let (sim_rep, c_sim) = sim::simulate(&a, &b, &planned.alg)?;
            let ccfg = CoordinatorConfig {
                tile: 8,
                artifacts_dir: have_artifacts.then(|| artifacts.clone()),
                plan: Some(std::sync::Arc::new(planned.prepared)),
                ..Default::default()
            };
            let t = Timer::start();
            let (rep, c) = coordinator::run(&a, &b, &planned.alg, &ccfg)?;
            let ms = t.elapsed_ms();
            // three-way validation
            let numeric_ok = c.approx_eq(&c_ref, 1e-3) && c_sim.approx_eq(&c_ref, 1e-9);
            let bracket_ok = sim_rep.max_send_recv() >= planned.comm_max
                && sim_rep.max_send_recv() <= 3 * planned.comm_max.max(1);
            let mults_ok = rep.tile_mults + rep.scalar_mults == flops;
            let ok = numeric_ok && bracket_ok && mults_ok;
            all_ok &= ok;
            println!(
                "{:<16} {:>5} {:>8.1} {:>10} {:>10} {:>11} {:>10} {:>9} {:>8} {:>8.1} {:>6}",
                kind.name(),
                planned.outcome.name(),
                planned.plan_ns as f64 / 1e6,
                planned.comm_max,
                sim_rep.max_send_recv(),
                rep.max_send_recv(),
                rep.tile_mults,
                rep.scalar_mults,
                rep.kernel_dispatches,
                ms,
                if ok { "PASS" } else { "FAIL" }
            );
        }
        println!();
    }
    assert!(all_ok, "end-to-end validation failed");
    println!("E2E PASS: planner (fingerprinted plan cache) → threaded expand/fold →");
    println!("PJRT tile kernel (JAX/Pallas AOT) → numerics == reference; realized");
    println!("communication within [1x, 3x] of the Lem. 4.2 hypergraph bound; and");
    println!("iteration 2's plans all served warm from the cache.");
    Ok(())
}
