//! END-TO-END driver: all three layers composed on a real workload.
//!
//! Pipeline (the paper's system, deployed):
//!   1. generate a scale-free MCL graph (Sec. 6.3 workload);
//!   2. build the hypergraph models, partition with the multilevel
//!      partitioner (the paper's contribution);
//!   3. lower the partition to a concrete parallel algorithm;
//!   4. execute it on the leader/worker coordinator — expand/fold message
//!      routing over threads, tile batches dispatched to the AOT-compiled
//!      JAX/Pallas kernel through PJRT (L1+L2), scalar fallback for open
//!      tile groups;
//!   5. validate numerics against the sequential reference SpGEMM and
//!      validate the realized communication against the hypergraph bound
//!      (Lem. 4.2) and the Lem. 4.3 simulator.
//!
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_distributed_spgemm
//! ```

use spgemm_hp::coordinator::{self, CoordinatorConfig};
use spgemm_hp::gen::{rmat, RmatParams};
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, PartitionerConfig};
use spgemm_hp::util::{Rng, Timer};
use spgemm_hp::{cost, sim, sparse};

fn main() -> spgemm_hp::Result<()> {
    let mut rng = Rng::new(20160711);
    let a = rmat(&RmatParams::social(10, 8.0), &mut rng)?;
    let b = a.clone();
    let flops = sparse::spgemm_flops(&a, &b)?;
    println!(
        "workload: squaring a scale-free graph, {}x{}, {} nnz, {} multiplications",
        a.nrows,
        a.ncols,
        a.nnz(),
        flops
    );
    let t = Timer::start();
    let c_ref = sparse::spgemm(&a, &b)?;
    println!("reference Gustavson SpGEMM: {} nnz in {:.1} ms\n", c_ref.nnz(), t.elapsed_ms());

    let p = 8;
    let artifacts = std::path::PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.txt").exists();
    if !have_artifacts {
        println!("NOTE: run `make artifacts` first for the PJRT path; using reference backend\n");
    }

    println!(
        "{:<16} {:>10} {:>10} {:>11} {:>10} {:>9} {:>9} {:>8} {:>8} {:>6}",
        "model",
        "bound_maxQ",
        "sim_words",
        "coord_words",
        "tile_mult",
        "scalar",
        "batches",
        "ms",
        "pjrt",
        "ok"
    );
    let mut all_ok = true;
    for kind in [
        ModelKind::RowWise,
        ModelKind::ColWise,
        ModelKind::OuterProduct,
        ModelKind::MonoA,
        ModelKind::MonoB,
        ModelKind::MonoC,
    ] {
        let model = build_model(&a, &b, kind, false)?;
        let cfg = PartitionerConfig { epsilon: 0.10, seed: 3, ..PartitionerConfig::new(p) };
        let part = partition(&model.h, &cfg)?;
        let bound = cost::evaluate(&model.h, &part, p)?;
        let alg = sim::lower(&model, &part, &a, &b, p)?;
        let (sim_rep, c_sim) = sim::simulate(&a, &b, &alg)?;
        let ccfg = CoordinatorConfig {
            tile: 8,
            artifacts_dir: have_artifacts.then(|| artifacts.clone()),
            ..Default::default()
        };
        let t = Timer::start();
        let (rep, c) = coordinator::run(&a, &b, &alg, &ccfg)?;
        let ms = t.elapsed_ms();
        // three-way validation
        let numeric_ok = c.approx_eq(&c_ref, 1e-3) && c_sim.approx_eq(&c_ref, 1e-9);
        let bracket_ok = sim_rep.max_send_recv() >= bound.comm_max
            && sim_rep.max_send_recv() <= 3 * bound.comm_max.max(1);
        let mults_ok = rep.tile_mults + rep.scalar_mults == flops;
        let ok = numeric_ok && bracket_ok && mults_ok;
        all_ok &= ok;
        println!(
            "{:<16} {:>10} {:>10} {:>11} {:>10} {:>9} {:>9} {:>8.1} {:>8} {:>6}",
            kind.name(),
            bound.comm_max,
            sim_rep.max_send_recv(),
            rep.max_send_recv(),
            rep.tile_mults,
            rep.scalar_mults,
            rep.kernel_dispatches,
            ms,
            rep.used_pjrt,
            if ok { "PASS" } else { "FAIL" }
        );
    }
    assert!(all_ok, "end-to-end validation failed");
    println!("\nE2E PASS: partitioner → algorithm lowering → threaded expand/fold →");
    println!("PJRT tile kernel (JAX/Pallas AOT) → numerics == reference; realized");
    println!("communication within [1x, 3x] of the Lem. 4.2 hypergraph bound.");
    Ok(())
}
