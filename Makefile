# spgemm-hp build entry points. `make ci` is the authoritative local gate
# (mirrors .github/workflows/ci.yml); everything else is convenience.

.PHONY: ci build test doc bench smoke artifacts clean

ci:
	scripts/ci.sh

build:
	cargo build --release

test:
	cargo test -q

# Rustdoc with broken intra-doc links / bad markdown as hard errors
# (the same gate CI runs).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Full self-timed bench suite (no criterion; see benches/*.rs).
bench:
	cargo bench

# The fast bench path CI runs; writes BENCH_spgemm.json and
# BENCH_partition.json (with the coarsen/initial/refine phase fields and
# the plan-cache cold/warm fields, whose presence is asserted like in CI).
smoke:
	cargo bench --bench spgemm_kernels -- --kernel auto --smoke --json BENCH_spgemm.json
	cargo bench --bench partitioner -- --smoke --threads 1,4 --json BENCH_partition.json \
		--plan-cache "$$(mktemp -d)"
	@for field in coarsen_ns initial_ns refine_ns mem_imbalance plan_cold_ns plan_warm_ns hit; do \
		grep -q "\"$$field\"" BENCH_partition.json || { echo "missing $$field"; exit 1; }; \
	done
	@for field in traffic_bytes dataflow exec_mode wire_bytes replans degraded final_workers; do \
		grep -q "\"$$field\"" BENCH_spgemm.json || { echo "missing $$field"; exit 1; }; \
	done

# AOT-compile the JAX/Pallas kernels to HLO text artifacts for the
# `pallas` runtime path. Requires python3 + jax (build time only; the
# rust binary never runs python).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

clean:
	cargo clean
	rm -f BENCH_spgemm.json BENCH_partition.json
