# spgemm-hp build entry points. `make ci` is the authoritative local gate
# (mirrors .github/workflows/ci.yml); everything else is convenience.

.PHONY: ci build test bench smoke artifacts clean

ci:
	scripts/ci.sh

build:
	cargo build --release

test:
	cargo test -q

# Full self-timed bench suite (no criterion; see benches/*.rs).
bench:
	cargo bench

# The fast bench path CI runs; writes BENCH_spgemm.json.
smoke:
	cargo bench --bench spgemm_kernels -- --kernel auto --smoke --json BENCH_spgemm.json

# AOT-compile the JAX/Pallas kernels to HLO text artifacts for the
# `pallas` runtime path. Requires python3 + jax (build time only; the
# rust binary never runs python).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

clean:
	cargo clean
	rm -f BENCH_spgemm.json
