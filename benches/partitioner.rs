//! Partitioner performance bench (criterion is unavailable offline; this
//! is a self-timed harness — run with `cargo bench --offline`).
//!
//! Times the multilevel partitioner across model kinds and hypergraph
//! sizes, the §Perf hot path of the system (the paper reports PaToH
//! times from seconds to 5 hours; relative model-to-model ratios are the
//! comparable signal).

use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, PartitionerConfig};
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;

fn main() {
    println!("== partitioner bench ==");
    let mut rng = Rng::new(5);

    // AMG A·P at two grid sizes; MCL squaring at two scales
    let workloads: Vec<(String, spgemm_hp::sparse::Csr, spgemm_hp::sparse::Csr)> = {
        let mut v = Vec::new();
        for n in [9usize, 12] {
            let a = gen::stencil27(n);
            let p = gen::smoothed_aggregation_prolongator(&a, n).unwrap();
            v.push((format!("amg-AP-n{n}"), a, p));
        }
        for scale in [9u32, 10] {
            let a = gen::rmat(&gen::RmatParams::social(scale, 8.0), &mut rng).unwrap();
            v.push((format!("mcl-rmat-s{scale}"), a.clone(), a));
        }
        v
    };

    println!(
        "{:<16} {:<14} {:>10} {:>10} {:>14}",
        "workload", "model", "vertices", "pins", "partition time"
    );
    for (name, a, b) in &workloads {
        for kind in
            [ModelKind::RowWise, ModelKind::OuterProduct, ModelKind::MonoA, ModelKind::FineGrained]
        {
            let model = build_model(a, b, kind, false).unwrap();
            let cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(16) };
            let iters = if model.h.num_vertices() > 100_000 { 1 } else { 3 };
            let stats = bench(0, iters, || partition(&model.h, &cfg).unwrap());
            println!(
                "{:<16} {:<14} {:>10} {:>10} {:>14}",
                name,
                kind.name(),
                model.h.num_vertices(),
                model.h.num_pins(),
                BenchStats::fmt_time(stats.median)
            );
        }
    }
}
