//! Partitioner quality + speed harness (criterion is unavailable
//! offline; this is a self-timed binary — run with `cargo bench`).
//!
//! Sweeps model × workload × p, recording *quality* (cut nets,
//! connectivity-(λ−1) volume, max boundary cost, computation and memory
//! imbalance) and *speed* (ns/op plus the coarsen / initial / refine
//! phase breakdown of [`spgemm_hp::partition::PhaseBreakdown`]) — the
//! partitioner is the planning stage whose cost must be amortizable, so
//! both where time goes and how it scales are tracked across commits
//! exactly like the kernels in `BENCH_spgemm.json`. A final sweep times
//! `PartitionerConfig::threads` on the largest workload and verifies the
//! bit-determinism contract while doing so; the per-phase fields are
//! what shows the parallel-matching coarsening speedup.
//!
//! Flags (after `--`):
//!
//! * `--smoke` — small workloads and a single iteration (the CI gate).
//! * `--json [path]` — write machine-readable records (model, workload,
//!   parts, threads, cut, volume, comm_max, imbalance, mem_imbalance,
//!   ns_per_op, coarsen_ns, initial_ns, refine_ns) to `path`, default
//!   `BENCH_partition.json`.
//! * `--parts 4,16` — part counts for the sweep.
//! * `--threads 1,2,4,8` — thread counts for the parallel planning sweep.
//!
//! ```bash
//! cargo bench --bench partitioner -- --smoke --json BENCH_partition.json
//! ```

use spgemm_hp::cli::Args;
use spgemm_hp::cost;
use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition_timed, PartitionerConfig, PhaseBreakdown};
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;
use spgemm_hp::{Error, Result};

/// One measured point, serialized to `BENCH_partition.json`.
struct Record {
    model: &'static str,
    workload: String,
    parts: usize,
    threads: usize,
    cut: usize,
    volume: u64,
    comm_max: u64,
    imbalance: f64,
    mem_imbalance: f64,
    ns_per_op: f64,
    phases: PhaseBreakdown,
}

fn write_json(path: &str, records: &[Record]) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "[")?;
    for (i, r) in records.iter().enumerate() {
        let comma = if i + 1 < records.len() { "," } else { "" };
        writeln!(
            f,
            "  {{\"model\": \"{}\", \"workload\": \"{}\", \"parts\": {}, \"threads\": {}, \
             \"cut\": {}, \"volume\": {}, \"comm_max\": {}, \"imbalance\": {:.4}, \
             \"mem_imbalance\": {:.4}, \"ns_per_op\": {:.1}, \"coarsen_ns\": {}, \
             \"initial_ns\": {}, \"refine_ns\": {}}}{comma}",
            r.model,
            r.workload,
            r.parts,
            r.threads,
            r.cut,
            r.volume,
            r.comm_max,
            r.imbalance,
            r.mem_imbalance,
            r.ns_per_op,
            r.phases.coarsen_ns,
            r.phases.initial_ns,
            r.phases.refine_ns
        )?;
    }
    writeln!(f, "]")?;
    f.flush()?;
    Ok(())
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("bench error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let smoke = args.has_flag("smoke");
    let json_path: Option<String> = match args.get("json") {
        Some(p) => Some(p.to_string()),
        None if args.has_flag("json") => Some("BENCH_partition.json".to_string()),
        None => None,
    };
    let parts_sweep = args.get_usize_list("parts", &[4, 16])?;
    let threads_sweep = args.get_usize_list("threads", &[1, 2, 4, 8])?;
    // one iteration in smoke mode and for huge models (the fine-grained
    // hypergraphs have one vertex per flop); three otherwise
    let iters_for = |nv: usize| if smoke || nv > 100_000 { 1 } else { 3 };
    let mut records: Vec<Record> = Vec::new();
    let mut rng = Rng::new(5);

    // the paper's three application classes, sized for the mode
    let workloads: Vec<(String, spgemm_hp::sparse::Csr, spgemm_hp::sparse::Csr)> = {
        let mut v = Vec::new();
        let stencil_n = if smoke { 6 } else { 10 };
        let a = gen::stencil27(stencil_n);
        let p = gen::smoothed_aggregation_prolongator(&a, stencil_n)?;
        v.push((format!("amg-AP-n{stencil_n}"), a, p));
        let lp_rows = if smoke { 160 } else { 512 };
        let lp = gen::lp_constraints(&gen::LpParams::pds_like(lp_rows, lp_rows * 3), &mut rng)?;
        let lpt = lp.transpose();
        v.push((format!("lp-pds-r{lp_rows}"), lp, lpt));
        let scale = if smoke { 8u32 } else { 10 };
        let m = gen::rmat(&gen::RmatParams::social(scale, 8.0), &mut rng)?;
        v.push((format!("mcl-rmat-s{scale}"), m.clone(), m));
        v
    };

    println!("== partitioner quality + speed (model x workload x p) ==");
    println!(
        "{:<16} {:<14} {:>4} {:>9} {:>9} {:>9} {:>9} {:>7} {:>7} {:>12} {:>22}",
        "workload",
        "model",
        "p",
        "vertices",
        "cut",
        "volume",
        "comm_max",
        "imbal",
        "mem_im",
        "time",
        "coarsen/initial/refine"
    );
    let models =
        [ModelKind::RowWise, ModelKind::OuterProduct, ModelKind::MonoC, ModelKind::FineGrained];
    for (name, a, b) in &workloads {
        for kind in models {
            let model = build_model(a, b, kind, false)?;
            for &p in &parts_sweep {
                let cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(p) };
                // deterministic per cfg, so the last timed run IS the result
                let mut part: Vec<u32> = Vec::new();
                let mut phases = PhaseBreakdown::default();
                let iters = iters_for(model.h.num_vertices());
                let stats = bench(0, iters, || {
                    let (pt, ph) = partition_timed(&model.h, &cfg).unwrap();
                    part = pt;
                    phases = ph;
                });
                let m = cost::evaluate(&model.h, &part, p)?;
                println!(
                    "{:<16} {:<14} {:>4} {:>9} {:>9} {:>9} {:>9} {:>7.3} {:>7.3} {:>12} {:>22}",
                    name,
                    kind.name(),
                    p,
                    model.h.num_vertices(),
                    m.cut_nets,
                    m.connectivity_volume,
                    m.comm_max,
                    m.comp_imbalance(),
                    m.mem_imbalance(),
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases)
                );
                records.push(Record {
                    model: kind.name(),
                    workload: name.clone(),
                    parts: p,
                    threads: 1,
                    cut: m.cut_nets,
                    volume: m.connectivity_volume,
                    comm_max: m.comm_max,
                    imbalance: m.comp_imbalance(),
                    mem_imbalance: m.mem_imbalance(),
                    ns_per_op: stats.median * 1e9,
                    phases,
                });
            }
        }
    }

    println!("\n== threaded planning (largest workload, monochrome-C) ==");
    let (tname, ta, tb) = workloads.last().expect("workloads nonempty");
    let model = build_model(ta, tb, ModelKind::MonoC, false)?;
    let p = *parts_sweep.last().unwrap_or(&16);
    let mut baseline: Option<(f64, Vec<u32>)> = None;
    for &t in &threads_sweep {
        let cfg = PartitionerConfig { epsilon: 0.05, threads: t, ..PartitionerConfig::new(p) };
        let mut part: Vec<u32> = Vec::new();
        let mut phases = PhaseBreakdown::default();
        let iters = iters_for(model.h.num_vertices());
        let stats = bench(0, iters, || {
            let (pt, ph) = partition_timed(&model.h, &cfg).unwrap();
            part = pt;
            phases = ph;
        });
        let m = cost::evaluate(&model.h, &part, p)?;
        match &baseline {
            None => {
                println!(
                    "{tname:<16} threads={t:<3} {:>12} {:>22}",
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases)
                );
                baseline = Some((stats.median, part.clone()));
            }
            Some((t1, p1)) => {
                // the determinism contract is part of the harness: any
                // drift across thread counts is a bug, not a data point
                if *p1 != part {
                    return Err(Error::Runtime(format!(
                        "partition not bit-identical at threads={t}"
                    )));
                }
                println!(
                    "{tname:<16} threads={t:<3} {:>12} {:>22}  ({:.2}x vs first)",
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases),
                    t1 / stats.median
                );
            }
        }
        records.push(Record {
            model: ModelKind::MonoC.name(),
            workload: format!("{tname}-threaded"),
            parts: p,
            threads: t,
            cut: m.cut_nets,
            volume: m.connectivity_volume,
            comm_max: m.comm_max,
            imbalance: m.comp_imbalance(),
            mem_imbalance: m.mem_imbalance(),
            ns_per_op: stats.median * 1e9,
            phases,
        });
    }

    if let Some(path) = json_path {
        write_json(&path, &records)?;
        println!("\nwrote {} records to {path}", records.len());
    }
    Ok(())
}

/// Compact `coarsen/initial/refine` milliseconds column.
fn fmt_phases(p: &PhaseBreakdown) -> String {
    format!(
        "{:.1}/{:.1}/{:.1} ms",
        p.coarsen_ns as f64 / 1e6,
        p.initial_ns as f64 / 1e6,
        p.refine_ns as f64 / 1e6
    )
}
