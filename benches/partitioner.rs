//! Partitioner quality + speed harness (criterion is unavailable
//! offline; this is a self-timed binary — run with `cargo bench`).
//!
//! Sweeps model × workload × p, recording *quality* (cut nets,
//! connectivity-(λ−1) volume, max boundary cost, computation and memory
//! imbalance) and *speed* (ns/op plus the coarsen / initial / refine
//! phase breakdown of [`spgemm_hp::partition::PhaseBreakdown`]) — the
//! partitioner is the planning stage whose cost must be amortizable, so
//! both where time goes and how it scales are tracked across commits
//! exactly like the kernels in `BENCH_spgemm.json`. A final sweep times
//! `PartitionerConfig::threads` on the largest workload and verifies the
//! bit-determinism contract while doing so; the per-phase fields are
//! what shows the parallel-matching coarsening speedup.
//!
//! A final plan-cache sweep times the inspector–executor planner cold
//! vs warm on the LP/MCL reuse workloads (same structure, fresh values),
//! enforcing `hit` + `plan_warm_ns < plan_cold_ns` in-harness — both
//! read from the planner's `plan_hit_total` / `plan_latency_ns` metric
//! series — and writing the timings into the JSON records.
//!
//! Flags (after `--`):
//!
//! * `--smoke` — small workloads and a single iteration (the CI gate).
//! * `--json [path]` — write machine-readable records (model, workload,
//!   parts, threads, cut, volume, comm_max, imbalance, mem_imbalance,
//!   ns_per_op, coarsen_ns, initial_ns, refine_ns; plan-cache rows
//!   instead carry model, workload, parts, volume, comm_max,
//!   plan_cold_ns, plan_warm_ns, hit, plan_hit_total; strategy rows
//!   carry strategy, workload, parts, expand, fold, volume, comm_max,
//!   ns_per_op) to `path`, default `BENCH_partition.json`.
//! * `--parts 4,16` — part counts for the sweep.
//! * `--threads 1,2,4,8` — thread counts for the parallel planning sweep.
//! * `--plan-cache DIR` — exercise the planner's *disk* tier in the
//!   plan-cache sweep (a `plansweep/` subdirectory is wiped first so the
//!   cold leg is genuinely cold); without it the memory tier is timed.
//!
//! ```bash
//! cargo bench --bench partitioner -- --smoke --json BENCH_partition.json
//! ```

use spgemm_hp::algorithm::{self, AlgorithmStrategy};
use spgemm_hp::cli::Args;
use spgemm_hp::cost;
use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition_timed, PartitionerConfig, PhaseBreakdown};
use spgemm_hp::planner::{Planner, PlannerConfig};
use spgemm_hp::util::json::{write_records, Json};
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;
use spgemm_hp::{Error, Result};

/// Cold/warm planner timings for the plan-cache rows, read back from the
/// planner's metric series (`plan_hit_total` / `plan_latency_ns` sum
/// deltas) rather than from `Planned`'s own fields — the bench doubles
/// as the consumer test of the public stats surface.
struct PlanTiming {
    cold_ns: u64,
    warm_ns: u64,
    hit: bool,
    /// Global `plan_hit_total` after the warm leg.
    hit_total: u64,
}

/// Communication profile of a lowered algorithm, for the strategy rows.
struct StrategyProfile {
    name: String,
    expand: u64,
    fold: u64,
}

/// One measured point, serialized to `BENCH_partition.json`.
struct Record {
    model: &'static str,
    workload: String,
    parts: usize,
    threads: usize,
    cut: usize,
    volume: u64,
    comm_max: u64,
    imbalance: f64,
    mem_imbalance: f64,
    ns_per_op: f64,
    phases: PhaseBreakdown,
    /// Present on plan-cache sweep rows only.
    planner: Option<PlanTiming>,
    /// Present on algorithm-strategy sweep rows only.
    strategy: Option<StrategyProfile>,
}

impl Record {
    fn to_json(&self) -> Json {
        if let Some(s) = &self.strategy {
            // strategy rows compare whole algorithms, not partitions of
            // one model, so cut/imbalance have no meaning here either
            return Json::obj(vec![
                ("strategy", Json::Str(s.name.clone())),
                ("workload", Json::Str(self.workload.clone())),
                ("parts", Json::U64(self.parts as u64)),
                ("expand", Json::U64(s.expand)),
                ("fold", Json::U64(s.fold)),
                ("volume", Json::U64(self.volume)),
                ("comm_max", Json::U64(self.comm_max)),
                ("ns_per_op", Json::Fixed(self.ns_per_op, 1)),
            ]);
        }
        match &self.planner {
            // plan-cache sweep rows carry only the fields that mean
            // something for a cached plan — fabricating cut/imbalance
            // values here would pollute cross-commit quality tracking
            Some(t) => Json::obj(vec![
                ("model", Json::Str(self.model.to_string())),
                ("workload", Json::Str(self.workload.clone())),
                ("parts", Json::U64(self.parts as u64)),
                ("volume", Json::U64(self.volume)),
                ("comm_max", Json::U64(self.comm_max)),
                ("plan_cold_ns", Json::U64(t.cold_ns)),
                ("plan_warm_ns", Json::U64(t.warm_ns)),
                ("hit", Json::Bool(t.hit)),
                ("plan_hit_total", Json::U64(t.hit_total)),
            ]),
            None => Json::obj(vec![
                ("model", Json::Str(self.model.to_string())),
                ("workload", Json::Str(self.workload.clone())),
                ("parts", Json::U64(self.parts as u64)),
                ("threads", Json::U64(self.threads as u64)),
                ("cut", Json::U64(self.cut as u64)),
                ("volume", Json::U64(self.volume)),
                ("comm_max", Json::U64(self.comm_max)),
                ("imbalance", Json::Fixed(self.imbalance, 4)),
                ("mem_imbalance", Json::Fixed(self.mem_imbalance, 4)),
                ("ns_per_op", Json::Fixed(self.ns_per_op, 1)),
                ("coarsen_ns", Json::U64(self.phases.coarsen_ns)),
                ("initial_ns", Json::U64(self.phases.initial_ns)),
                ("refine_ns", Json::U64(self.phases.refine_ns)),
            ]),
        }
    }
}

fn write_json(path: &str, records: &[Record]) -> Result<()> {
    let rows: Vec<Json> = records.iter().map(Record::to_json).collect();
    write_records(path, &rows)
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("bench error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.check_known(&["smoke", "json", "parts", "threads", "plan-cache"])?;
    let smoke = args.has_flag("smoke");
    let json_path: Option<String> = match args.get("json") {
        Some(p) => Some(p.to_string()),
        None if args.has_flag("json") => Some("BENCH_partition.json".to_string()),
        None => None,
    };
    let parts_sweep = args.get_usize_list("parts", &[4, 16])?;
    let threads_sweep = args.get_usize_list("threads", &[1, 2, 4, 8])?;
    // one iteration in smoke mode and for huge models (the fine-grained
    // hypergraphs have one vertex per flop); three otherwise
    let iters_for = |nv: usize| if smoke || nv > 100_000 { 1 } else { 3 };
    let mut records: Vec<Record> = Vec::new();
    let mut rng = Rng::new(5);

    // the paper's three application classes, sized for the mode
    let workloads: Vec<(String, spgemm_hp::sparse::Csr, spgemm_hp::sparse::Csr)> = {
        let mut v = Vec::new();
        let stencil_n = if smoke { 6 } else { 10 };
        let a = gen::stencil27(stencil_n);
        let p = gen::smoothed_aggregation_prolongator(&a, stencil_n)?;
        v.push((format!("amg-AP-n{stencil_n}"), a, p));
        let lp_rows = if smoke { 160 } else { 512 };
        let lp = gen::lp_constraints(&gen::LpParams::pds_like(lp_rows, lp_rows * 3), &mut rng)?;
        let lpt = lp.transpose();
        v.push((format!("lp-pds-r{lp_rows}"), lp, lpt));
        let scale = if smoke { 8u32 } else { 10 };
        let m = gen::rmat(&gen::RmatParams::social(scale, 8.0), &mut rng)?;
        v.push((format!("mcl-rmat-s{scale}"), m.clone(), m));
        v
    };

    println!("== partitioner quality + speed (model x workload x p) ==");
    println!(
        "{:<16} {:<14} {:>4} {:>9} {:>9} {:>9} {:>9} {:>7} {:>7} {:>12} {:>22}",
        "workload",
        "model",
        "p",
        "vertices",
        "cut",
        "volume",
        "comm_max",
        "imbal",
        "mem_im",
        "time",
        "coarsen/initial/refine"
    );
    let models =
        [ModelKind::RowWise, ModelKind::OuterProduct, ModelKind::MonoC, ModelKind::FineGrained];
    for (name, a, b) in &workloads {
        for kind in models {
            let model = build_model(a, b, kind, false)?;
            for &p in &parts_sweep {
                let cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(p) };
                // deterministic per cfg, so the last timed run IS the result
                let mut part: Vec<u32> = Vec::new();
                let mut phases = PhaseBreakdown::default();
                let iters = iters_for(model.h.num_vertices());
                let stats = bench(0, iters, || {
                    let (pt, ph) = partition_timed(&model.h, &cfg).unwrap();
                    part = pt;
                    phases = ph;
                });
                let m = cost::evaluate(&model.h, &part, p)?;
                println!(
                    "{:<16} {:<14} {:>4} {:>9} {:>9} {:>9} {:>9} {:>7.3} {:>7.3} {:>12} {:>22}",
                    name,
                    kind.name(),
                    p,
                    model.h.num_vertices(),
                    m.cut_nets,
                    m.connectivity_volume,
                    m.comm_max,
                    m.comp_imbalance(),
                    m.mem_imbalance(),
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases)
                );
                records.push(Record {
                    model: kind.name(),
                    workload: name.clone(),
                    parts: p,
                    threads: 1,
                    cut: m.cut_nets,
                    volume: m.connectivity_volume,
                    comm_max: m.comm_max,
                    imbalance: m.comp_imbalance(),
                    mem_imbalance: m.mem_imbalance(),
                    ns_per_op: stats.median * 1e9,
                    phases,
                    planner: None,
                    strategy: None,
                });
            }
        }
    }

    // --- algorithm strategies: model-aware vs sparsity-oblivious -----------
    // The same workloads lowered end-to-end through each AlgorithmStrategy,
    // timing the full planning path (model build + partition for the
    // hypergraph rows, closed-form ownership for SUMMA/split-3D) and
    // recording the simulator-measured expand/fold split. The modeled
    // connectivity volume must equal what the simulator moves — any gap
    // is an accounting bug, not a data point.
    println!("\n== algorithm strategies: model-aware vs sparsity-oblivious ==");
    let strategy_sweep = [
        AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::FineGrained, with_nz: false },
        AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::RowWise, with_nz: false },
        AlgorithmStrategy::SparseSumma { grid: (0, 0) },
        AlgorithmStrategy::Split3d { grid: (0, 0), layers: 0 },
    ];
    let sp = *parts_sweep.first().unwrap_or(&4);
    println!(
        "{:<16} {:<16} {:>4} {:>9} {:>9} {:>9} {:>9} {:>12}",
        "workload", "strategy", "p", "expand", "fold", "volume", "comm_max", "plan time"
    );
    for (name, a, b) in &workloads {
        for strat in &strategy_sweep {
            let cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(sp) };
            let label = strat.resolve(sp)?.name();
            let mut alg = None;
            let stats = bench(0, 1, || alg = Some(strat.lower(a, b, &cfg).unwrap()));
            let alg = alg.expect("bench ran at least once");
            let (comm_max, volume) = algorithm::connectivity_metrics(a, b, &alg)?;
            let (rep, _) = spgemm_hp::sim::simulate(a, b, &alg)?;
            if volume != rep.total_volume() {
                return Err(Error::Runtime(format!(
                    "{name}/{label}: modeled volume {volume} != simulated {}",
                    rep.total_volume()
                )));
            }
            println!(
                "{:<16} {:<16} {:>4} {:>9} {:>9} {:>9} {:>9} {:>12}",
                name,
                label,
                sp,
                rep.expand_volume,
                rep.fold_volume,
                volume,
                comm_max,
                BenchStats::fmt_time(stats.median)
            );
            records.push(Record {
                model: "strategy",
                workload: name.clone(),
                parts: sp,
                threads: 1,
                cut: 0,
                volume,
                comm_max,
                imbalance: 1.0,
                mem_imbalance: 1.0,
                ns_per_op: stats.median * 1e9,
                phases: PhaseBreakdown::default(),
                planner: None,
                strategy: Some(StrategyProfile {
                    name: label,
                    expand: rep.expand_volume,
                    fold: rep.fold_volume,
                }),
            });
        }
    }

    println!("\n== threaded planning (largest workload, monochrome-C) ==");
    let (tname, ta, tb) = workloads.last().expect("workloads nonempty");
    let model = build_model(ta, tb, ModelKind::MonoC, false)?;
    let p = *parts_sweep.last().unwrap_or(&16);
    let mut baseline: Option<(f64, Vec<u32>)> = None;
    for &t in &threads_sweep {
        let cfg = PartitionerConfig { epsilon: 0.05, threads: t, ..PartitionerConfig::new(p) };
        let mut part: Vec<u32> = Vec::new();
        let mut phases = PhaseBreakdown::default();
        let iters = iters_for(model.h.num_vertices());
        let stats = bench(0, iters, || {
            let (pt, ph) = partition_timed(&model.h, &cfg).unwrap();
            part = pt;
            phases = ph;
        });
        let m = cost::evaluate(&model.h, &part, p)?;
        match &baseline {
            None => {
                println!(
                    "{tname:<16} threads={t:<3} {:>12} {:>22}",
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases)
                );
                baseline = Some((stats.median, part.clone()));
            }
            Some((t1, p1)) => {
                // the determinism contract is part of the harness: any
                // drift across thread counts is a bug, not a data point
                if *p1 != part {
                    return Err(Error::Runtime(format!(
                        "partition not bit-identical at threads={t}"
                    )));
                }
                println!(
                    "{tname:<16} threads={t:<3} {:>12} {:>22}  ({:.2}x vs first)",
                    BenchStats::fmt_time(stats.median),
                    fmt_phases(&phases),
                    t1 / stats.median
                );
            }
        }
        records.push(Record {
            model: ModelKind::MonoC.name(),
            workload: format!("{tname}-threaded"),
            parts: p,
            threads: t,
            cut: m.cut_nets,
            volume: m.connectivity_volume,
            comm_max: m.comm_max,
            imbalance: m.comp_imbalance(),
            mem_imbalance: m.mem_imbalance(),
            ns_per_op: stats.median * 1e9,
            phases,
            planner: None,
            strategy: None,
        });
    }

    // --- plan cache: cold vs warm on the reuse workloads -------------------
    // LP rescales B's values per IPM iteration (same pattern -> must hit);
    // MCL squares the same matrix every iteration. The warm leg goes
    // through a FRESH planner when --plan-cache is given, so the disk
    // tier (decode + verify + rebind) is what gets timed.
    println!("\n== plan cache: cold vs warm (inspector-executor amortization) ==");
    let plan_dir: Option<std::path::PathBuf> =
        args.get("plan-cache").map(|d| std::path::Path::new(d).join("plansweep"));
    if let Some(d) = &plan_dir {
        let _ = std::fs::remove_dir_all(d); // guarantee the cold leg is cold
    }
    let mk_planner = || {
        Planner::new(PlannerConfig {
            cache_dir: plan_dir.clone(),
            capacity: 8,
            ..Default::default()
        })
    };
    let (_, lp_a, lp_b) = &workloads[1];
    let lp_warm_b =
        spgemm_hp::sparse::ops::scale_rows(lp_b, &gen::lp::ipm_scaling(lp_b.nrows, &mut rng))?;
    let (_, mcl_a, mcl_b) = workloads.last().expect("workloads nonempty");
    let cases = [
        ("lp-reuse", ModelKind::OuterProduct, lp_a, lp_b, &lp_warm_b),
        ("mcl-reuse", ModelKind::MonoC, mcl_a, mcl_b, mcl_b),
    ];
    println!(
        "{:<12} {:<14} {:>12} {:>12} {:>9} {:>6}",
        "workload", "model", "cold", "warm", "speedup", "hit"
    );
    // The gate reads the planner's public metric series instead of its
    // private timing fields: hit/miss from `plan_hit_total` deltas and
    // cold/warm latency from the `plan_latency_ns` histogram's exact sum.
    let metrics = spgemm_hp::obs::metrics::global();
    let lat_sum = || metrics.histogram("plan_latency_ns").map(|h| h.sum).unwrap_or(0);
    for (label, kind, a, b_cold, b_warm) in cases {
        let cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(p) };
        let mut cold_planner = mk_planner()?;
        let hits_before = metrics.counter("plan_hit_total");
        let sum_before = lat_sum();
        let _cold_plan = cold_planner.plan_or_build(a, b_cold, kind, &cfg, 8)?;
        let sum_after_cold = lat_sum();
        let cold_ns = sum_after_cold - sum_before;
        if metrics.counter("plan_hit_total") != hits_before {
            return Err(Error::Runtime(format!("{label}: cold leg unexpectedly hit the cache")));
        }
        let warm_plan = if plan_dir.is_some() {
            mk_planner()?.plan_or_build(a, b_warm, kind, &cfg, 8)?
        } else {
            cold_planner.plan_or_build(a, b_warm, kind, &cfg, 8)?
        };
        let warm_ns = lat_sum() - sum_after_cold;
        let hit_total = metrics.counter("plan_hit_total");
        let hit = hit_total == hits_before + 1;
        // amortization is the harness contract, like bit-identity above:
        // a warm plan that misses, or is no faster than replanning, is a
        // planner bug rather than a data point
        if !hit {
            return Err(Error::Runtime(format!("{label}: warm leg missed the plan cache")));
        }
        if warm_ns >= cold_ns {
            return Err(Error::Runtime(format!(
                "{label}: warm plan ({warm_ns} ns) not faster than cold ({cold_ns} ns)"
            )));
        }
        println!(
            "{:<12} {:<14} {:>12} {:>12} {:>8.1}x {:>6}",
            label,
            kind.name(),
            BenchStats::fmt_time(cold_ns as f64 / 1e9),
            BenchStats::fmt_time(warm_ns as f64 / 1e9),
            cold_ns as f64 / warm_ns.max(1) as f64,
            if hit { "hit" } else { "miss" }
        );
        records.push(Record {
            model: kind.name(),
            workload: label.to_string(),
            parts: p,
            threads: 1,
            cut: 0,
            volume: warm_plan.volume,
            comm_max: warm_plan.comm_max,
            imbalance: 1.0,
            mem_imbalance: 1.0,
            ns_per_op: warm_ns as f64,
            phases: PhaseBreakdown::default(),
            planner: Some(PlanTiming { cold_ns, warm_ns, hit, hit_total }),
            strategy: None,
        });
    }

    if let Some(path) = json_path {
        write_json(&path, &records)?;
        println!("\nwrote {} records to {path}", records.len());
    }
    Ok(())
}

/// Compact `coarsen/initial/refine` milliseconds column.
fn fmt_phases(p: &PhaseBreakdown) -> String {
    format!(
        "{:.1}/{:.1}/{:.1} ms",
        p.coarsen_ns as f64 / 1e6,
        p.initial_ns as f64 / 1e6,
        p.refine_ns as f64 / 1e6
    )
}
