//! Regenerates every table and figure of the paper's evaluation — the
//! `cargo bench` entry point for the reproduction (see DESIGN.md
//! §Experiment-index and EXPERIMENTS.md for the recorded outputs).
//!
//! Scale via SPGEMM_HP_SCALE (1 = quick, 2 = default figures, 3 = big).

use spgemm_hp::repro::{self, figures};
use spgemm_hp::util::Timer;

fn main() {
    let scale: u32 = std::env::var("SPGEMM_HP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let seed = 20160711u64;
    println!("== paper-figure regeneration (scale {scale}) ==");

    let t = Timer::start();
    let rows = figures::table2(scale, seed).expect("table2");
    figures::print_table2(&rows);
    println!("[table2 in {:.1} s]", t.elapsed().as_secs_f64());

    let t = Timer::start();
    let rows = figures::fig7(scale, seed, &figures::FIG7_MODELS).expect("fig7");
    repro::print_rows("Fig. 7 — AMG weak scaling (A·P and Pᵀ(AP))", &rows);
    println!("[fig7 in {:.1} s]", t.elapsed().as_secs_f64());

    let t = Timer::start();
    let rows = figures::fig8(scale, seed, &figures::FIG8_MODELS).expect("fig8");
    repro::print_rows("Fig. 8 — LP normal equations, strong scaling", &rows);
    println!("[fig8 in {:.1} s]", t.elapsed().as_secs_f64());

    let t = Timer::start();
    let rows = figures::fig9(scale, seed, &figures::FIG9_MODELS).expect("fig9");
    repro::print_rows("Fig. 9 — MCL squaring, strong scaling", &rows);
    println!("[fig9 in {:.1} s]", t.elapsed().as_secs_f64());

    println!("\n== eq. (1) bound comparison ==");
    for r in figures::bounds_comparison(seed).expect("bounds") {
        println!(
            "{:<16} p={:<3} hypergraph={:<8} eq1_dep={:<10.0} eq1_ind={:<10.0} trivial={:.0}",
            r.instance,
            r.p,
            r.hypergraph_comm,
            r.eq1_memory_dependent,
            r.eq1_memory_independent,
            r.trivial
        );
    }

    println!("\n== sequential two-level memory (Thm. 4.10) ==");
    for r in figures::sequential_experiment(seed).expect("seq") {
        println!(
            "M={:<6} row-major={:<8} blocked={:<8} HK={:<8.0} trivial={:.0}",
            r.memory, r.row_major, r.hypergraph_blocked, r.hong_kung_bound, r.trivial_bound
        );
    }
}
