//! SpGEMM substrate + kernel-path benches: Gustavson numeric multiply
//! (sequential and row-block threaded), hypergraph construction, and the
//! tile-product engine (PJRT vs the pure-rust reference backend).
//!
//! Flags (after `--`):
//!
//! * `--smoke` — small workloads and few iterations (the CI gate).
//! * `--json [path]` — write machine-readable records (kernel, workload,
//!   threads, ns/op) to `path`, default `BENCH_spgemm.json`.
//! * `--threads 1,2,4,8` — thread counts for the parallel-SpGEMM sweep.
//! * `--kernel auto|sortmerge|densespa|hashaccum|all` — restrict the
//!   RowKernel strategy sweep (default `all`).
//!
//! A dataflow sweep replays each kernel workload through the storage
//! traffic simulator under the static tile and the adaptive
//! (`Dataflow::Auto`) tile search, writing `traffic_bytes`/`dataflow`
//! into the JSON rows and enforcing *adaptive never moves more bytes
//! than static* in-harness.
//!
//! ```bash
//! cargo bench --bench spgemm_kernels -- --kernel auto --smoke --json BENCH_spgemm.json
//! ```

use spgemm_hp::algorithm::AlgorithmStrategy;
use spgemm_hp::cli::Args;
use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, fine_grained, ModelKind};
use spgemm_hp::partition::PartitionerConfig;
use spgemm_hp::runtime::Engine;
use spgemm_hp::sim::{self, simulate, spgemm_parallel, spgemm_parallel_with};
use spgemm_hp::sparse::{self, KernelKind};
use spgemm_hp::util::json::{write_records, Json};
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;
use spgemm_hp::{Error, Result};

/// One measured point, serialized to `BENCH_spgemm.json`.
struct Record {
    kernel: &'static str,
    workload: String,
    threads: usize,
    ns_per_op: f64,
    /// Simulated cache traffic; present on dataflow sweep rows only.
    traffic_bytes: Option<u64>,
    /// `"static"` or `"auto"`; present on dataflow sweep rows only.
    dataflow: Option<&'static str>,
    /// `"simulated"` or `"processes"`; present on executor rows only.
    exec_mode: Option<&'static str>,
    /// Total framed bytes on the worker pipes; process-executor rows only.
    wire_bytes: Option<u64>,
    /// Payload-carrying framed bytes (Send/Deliver/ResultC); process rows only.
    wire_data_bytes: Option<u64>,
    /// Control framed bytes (everything else); process rows only.
    wire_ctl_bytes: Option<u64>,
    /// Plans built from scratch; elastic-executor rows only.
    replans: Option<u64>,
    /// Mid-epoch degradations to p−1; elastic-executor rows only.
    degraded: Option<u64>,
    /// Worker count when the run finished; elastic-executor rows only.
    final_workers: Option<usize>,
}

impl Record {
    fn new(kernel: &'static str, workload: String, threads: usize, ns_per_op: f64) -> Record {
        Record {
            kernel,
            workload,
            threads,
            ns_per_op,
            traffic_bytes: None,
            dataflow: None,
            exec_mode: None,
            wire_bytes: None,
            wire_data_bytes: None,
            wire_ctl_bytes: None,
            replans: None,
            degraded: None,
            final_workers: None,
        }
    }

    /// The record as one `BENCH_spgemm.json` row (field order is the
    /// schema the CI grep-gates key on).
    fn to_json(&self) -> Json {
        let mut row = Json::obj(vec![
            ("kernel", Json::Str(self.kernel.to_string())),
            ("workload", Json::Str(self.workload.clone())),
            ("threads", Json::U64(self.threads as u64)),
            ("ns_per_op", Json::Fixed(self.ns_per_op, 1)),
        ]);
        if let Some(tb) = self.traffic_bytes {
            row.push("traffic_bytes", Json::U64(tb));
        }
        if let Some(df) = self.dataflow {
            row.push("dataflow", Json::Str(df.to_string()));
        }
        if let Some(em) = self.exec_mode {
            row.push("exec_mode", Json::Str(em.to_string()));
        }
        if let Some(wb) = self.wire_bytes {
            row.push("wire_bytes", Json::U64(wb));
        }
        if let Some(db) = self.wire_data_bytes {
            row.push("wire_data_bytes", Json::U64(db));
        }
        if let Some(cb) = self.wire_ctl_bytes {
            row.push("wire_ctl_bytes", Json::U64(cb));
        }
        if let Some(rp) = self.replans {
            row.push("replans", Json::U64(rp));
        }
        if let Some(dg) = self.degraded {
            row.push("degraded", Json::U64(dg));
        }
        if let Some(fw) = self.final_workers {
            row.push("final_workers", Json::U64(fw as u64));
        }
        row
    }
}

fn write_json(path: &str, records: &[Record]) -> Result<()> {
    let rows: Vec<Json> = records.iter().map(Record::to_json).collect();
    write_records(path, &rows)
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("bench error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.check_known(&["smoke", "json", "threads", "kernel"])?;
    let smoke = args.has_flag("smoke");
    let json_path: Option<String> = match args.get("json") {
        Some(p) => Some(p.to_string()),
        None if args.has_flag("json") => Some("BENCH_spgemm.json".to_string()),
        None => None,
    };
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8])?;
    let kernels: Vec<KernelKind> = match args.get("kernel") {
        None | Some("all") => KernelKind::ALL.to_vec(),
        Some(s) => vec![KernelKind::parse(s)
            .ok_or_else(|| Error::Config(format!("--kernel: unrecognized value {s}")))?],
    };
    let iters = if smoke { 3 } else { 5 };
    let mut records: Vec<Record> = Vec::new();
    let mut rng = Rng::new(3);

    println!("== Gustavson SpGEMM (sequential) ==");
    let stencil_n = if smoke { 10 } else { 16 };
    let rmat_scale = if smoke { 9 } else { 12 };
    let workloads = [
        (format!("stencil27-n{stencil_n}"), gen::stencil27(stencil_n)),
        (
            format!("rmat-s{rmat_scale}"),
            gen::rmat(&gen::RmatParams::social(rmat_scale, 8.0), &mut rng)?,
        ),
    ];
    let mut seq_stats = Vec::with_capacity(workloads.len());
    for (name, a) in &workloads {
        let flops = sparse::spgemm_flops(a, a)?;
        let s = bench(1, iters, || sparse::spgemm(a, a).unwrap());
        println!(
            "{name:<22} {:>12} mults  {:>12}  ({:.1} Mmult/s)",
            flops,
            BenchStats::fmt_time(s.median),
            flops as f64 / s.median / 1e6
        );
        records.push(Record::new("spgemm", name.clone(), 1, s.median * 1e9));
        seq_stats.push(s);
    }

    println!("\n== row-block parallel Gustavson (spgemm_parallel) ==");
    let (par_name, par_a) = &workloads[1]; // the RMAT workload (skewed rows)
    let seq = seq_stats[1]; // reuse the sequential measurement from above
    println!("{par_name:<22} sequential baseline: {:>12}", BenchStats::fmt_time(seq.median));
    let mut best_speedup = 0.0f64;
    for &t in &threads {
        let s = bench(1, iters, || spgemm_parallel(par_a, par_a, t).unwrap());
        let speedup = seq.median / s.median;
        best_speedup = best_speedup.max(speedup);
        println!(
            "{par_name:<22} threads={t:<3} {:>12}  ({speedup:.2}x vs sequential)",
            BenchStats::fmt_time(s.median)
        );
        records.push(Record::new("spgemm_parallel", par_name.clone(), t, s.median * 1e9));
    }
    if threads.iter().any(|&t| t > 1) {
        println!("best speedup: {best_speedup:.2}x");
    }

    println!("\n== RowKernel strategies (kernel x workload x threads) ==");
    // a third, hypersparse workload so each accumulator has a regime to win
    let er_n = if smoke { 512 } else { 4096 };
    let er = gen::erdos_renyi(er_n, er_n, 4.0, &mut rng)?;
    let kernel_workloads: Vec<(String, &sparse::Csr)> = vec![
        (workloads[0].0.clone(), &workloads[0].1),
        (workloads[1].0.clone(), &workloads[1].1),
        (format!("er-n{er_n}"), &er),
    ];
    for &kind in &kernels {
        for (name, a) in &kernel_workloads {
            for &t in &threads {
                let s = bench(1, iters, || spgemm_parallel_with(a, a, t, kind).unwrap());
                println!(
                    "{:<10} {name:<22} threads={t:<3} {:>12}",
                    kind.name(),
                    BenchStats::fmt_time(s.median)
                );
                records.push(Record::new(kind.name(), name.clone(), t, s.median * 1e9));
            }
        }
    }

    println!("\n== dataflow: static vs adaptive (simulated cache traffic) ==");
    // The Dataflow::Auto planner contract, enforced where it is measured:
    // the static tile is Auto's first candidate and ties keep it, so an
    // adaptive plan that moves more bytes than static is a planner bug,
    // not a data point. ns/op records what each leg costs to *plan*.
    let cache = sim::CacheConfig::default();
    let static_tile = 8usize;
    for (name, a) in &kernel_workloads {
        let sched = sim::tiled_schedule(a, a, static_tile, static_tile * 8);
        let mut static_bytes = 0u64;
        let s_static = bench(0, 1, || {
            static_bytes = sim::simulate_traffic(a, a, &sched, &cache).unwrap().total();
        });
        let mut pick = (static_tile, 0u64);
        let s_auto = bench(0, 1, || {
            pick = sim::traffic::choose_plan_tile(a, a, &cache, static_tile).unwrap();
        });
        let (auto_tile, auto_bytes) = pick;
        if auto_bytes > static_bytes {
            return Err(Error::Runtime(format!(
                "{name}: adaptive dataflow moved {auto_bytes} bytes > static {static_bytes}"
            )));
        }
        println!(
            "{name:<22} static(tile={static_tile}) {static_bytes:>12} B   \
             auto(tile={auto_tile}) {auto_bytes:>12} B  ({:.2}x)",
            static_bytes as f64 / auto_bytes.max(1) as f64
        );
        records.push(Record {
            traffic_bytes: Some(static_bytes),
            dataflow: Some("static"),
            ..Record::new("traffic", name.clone(), 1, s_static.median * 1e9)
        });
        records.push(Record {
            traffic_bytes: Some(auto_bytes),
            dataflow: Some("auto"),
            ..Record::new("traffic", name.clone(), 1, s_auto.median * 1e9)
        });
    }

    println!("\n== algorithm-strategy execution (simulate, expand+mult+fold) ==");
    // the distributed-memory executor under each AlgorithmStrategy on a
    // stencil workload: same C, different data movement, so ns/op tracks
    // how much the schedule costs to execute rather than to plan. Sized
    // below the main stencil — the fine-grained row plans one vertex per
    // flop and its partition time would dwarf the execution being timed.
    let sim_n = if smoke { 6 } else { 8 };
    let sim_name = format!("stencil27-n{sim_n}");
    let sim_a = &gen::stencil27(sim_n);
    let sim_p = 4usize;
    let sim_cfg = PartitionerConfig { epsilon: 0.05, ..PartitionerConfig::new(sim_p) };
    for strat in [
        AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::RowWise, with_nz: false },
        AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::FineGrained, with_nz: false },
        AlgorithmStrategy::SparseSumma { grid: (0, 0) },
        AlgorithmStrategy::Split3d { grid: (0, 0), layers: 0 },
    ] {
        let label = strat.resolve(sim_p)?.name();
        let alg = strat.lower(sim_a, sim_a, &sim_cfg)?;
        let s = bench(1, iters, || simulate(sim_a, sim_a, &alg).unwrap());
        println!("{label:<16} {sim_name:<22} {:>12}", BenchStats::fmt_time(s.median));
        records.push(Record::new("simulate", format!("{sim_name}-{label}"), 1, s.median * 1e9));
    }

    println!("\n== process executor: measured wire traffic vs model ==");
    // Real worker OS processes over pipes. run_processes cross-checks the
    // measured per-worker payload entries against the plan's modeled
    // volumes on every run and errors on any mismatch, so a green row
    // here IS the measured == modeled property, enforced in-run.
    {
        use spgemm_hp::coordinator::{self, exec};
        let pe_a = &gen::stencil27(if smoke { 5 } else { 6 });
        let pe_p = 2usize;
        let strat =
            AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::RowWise, with_nz: false };
        let alg = strat.lower(pe_a, pe_a, &PartitionerConfig::new(pe_p))?;
        let ccfg = coordinator::CoordinatorConfig {
            exec: exec::ExecMode::Processes,
            worker_exe: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_spgemm-hp"))),
            ..Default::default()
        };
        let workload = format!("stencil27-row-p{pe_p}");
        match exec::run_processes(pe_a, pe_a, &alg, &ccfg) {
            Ok((rep, measured, _c)) => {
                let s = bench(0, iters, || {
                    exec::run_processes(pe_a, pe_a, &alg, &ccfg).unwrap();
                });
                println!(
                    "row p={pe_p}: {} payload words, {} wire bytes ({} data + {} ctl), \
                     {:>12}/run",
                    rep.total_volume(),
                    measured.wire_bytes,
                    measured.wire_data_bytes,
                    measured.wire_ctl_bytes,
                    BenchStats::fmt_time(s.median)
                );
                records.push(Record {
                    exec_mode: Some("processes"),
                    wire_bytes: Some(measured.wire_bytes),
                    wire_data_bytes: Some(measured.wire_data_bytes),
                    wire_ctl_bytes: Some(measured.wire_ctl_bytes),
                    ..Record::new("exec_processes", workload, 1, s.median * 1e9)
                });
            }
            Err(e) => {
                // keep the JSON schema stable for the CI field gate even
                // where the sandbox forbids spawning
                println!("(process executor unavailable here: {e}; recording simulated fallback)");
                let scfg = coordinator::CoordinatorConfig::default();
                let s = bench(0, iters, || {
                    coordinator::run(pe_a, pe_a, &alg, &scfg).unwrap();
                });
                records.push(Record {
                    exec_mode: Some("simulated"),
                    wire_bytes: Some(0),
                    wire_data_bytes: Some(0),
                    wire_ctl_bytes: Some(0),
                    ..Record::new("exec_processes", workload, 1, s.median * 1e9)
                });
            }
        }
    }

    println!("\n== elastic process executor: shrink re-plan + degraded retries ==");
    // MCL-style repeated A² with a scheduled leave between iterations:
    // the driver re-plans at every membership and run_elastic checks
    // measured == modeled traffic per epoch in-run, so a green row here
    // carries the elastic degradation contract too.
    {
        use spgemm_hp::coordinator::{self, exec};
        use spgemm_hp::planner::Planner;
        let el_a = &gen::stencil27(5);
        let el_p = 3usize;
        let strat =
            AlgorithmStrategy::HypergraphPartitioned { model: ModelKind::RowWise, with_nz: false };
        let opts = exec::ElasticOpts {
            strategy: strat,
            pcfg: PartitionerConfig::new(el_p),
            tile: 8,
            min_workers: 2,
            iters: 2,
            schedule: vec![exec::MembershipEvent {
                before_iter: 1,
                change: exec::MemberChange::Leave(1),
            }],
        };
        let ccfg = coordinator::CoordinatorConfig {
            exec: exec::ExecMode::Processes,
            worker_exe: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_spgemm-hp"))),
            ..Default::default()
        };
        let workload = format!("stencil27-row-elastic-p{el_p}");
        let mut probe = Planner::in_memory();
        match exec::run_elastic(el_a, el_a, &mut probe, &opts, &ccfg) {
            Ok((rep, _cs)) => {
                let s = bench(0, iters, || {
                    let mut planner = Planner::in_memory();
                    exec::run_elastic(el_a, el_a, &mut planner, &opts, &ccfg).unwrap();
                });
                println!(
                    "row p={el_p}->{}: {} epochs, {} replans, {} degraded, {} wire bytes, \
                     {:>12}/run",
                    rep.final_workers,
                    rep.epochs,
                    rep.replans,
                    rep.degraded,
                    rep.wire_bytes,
                    BenchStats::fmt_time(s.median)
                );
                records.push(Record {
                    exec_mode: Some("processes"),
                    wire_bytes: Some(rep.wire_bytes),
                    replans: Some(rep.replans),
                    degraded: Some(rep.degraded),
                    final_workers: Some(rep.final_workers),
                    ..Record::new("exec_elastic", workload, 1, s.median * 1e9)
                });
            }
            Err(e) => {
                // keep the JSON schema stable for the CI field gate even
                // where the sandbox forbids spawning
                println!("(elastic executor unavailable here: {e}; recording simulated fallback)");
                let alg = strat.lower(el_a, el_a, &PartitionerConfig::new(el_p))?;
                let scfg = coordinator::CoordinatorConfig::default();
                let s = bench(0, iters, || {
                    coordinator::run(el_a, el_a, &alg, &scfg).unwrap();
                });
                records.push(Record {
                    exec_mode: Some("simulated"),
                    wire_bytes: Some(0),
                    replans: Some(0),
                    degraded: Some(0),
                    final_workers: Some(0),
                    ..Record::new("exec_elastic", workload, 1, s.median * 1e9)
                });
            }
        }
    }

    println!("\n== hypergraph model construction ==");
    let grid_n = if smoke { 9 } else { 12 };
    let a = gen::stencil27(grid_n);
    let p = gen::smoothed_aggregation_prolongator(&a, grid_n)?;
    for kind in [ModelKind::FineGrained, ModelKind::RowWise, ModelKind::MonoC] {
        let s = bench(1, iters, || build_model(&a, &p, kind, false).unwrap());
        let m = build_model(&a, &p, kind, false)?;
        println!(
            "{:<16} |V|={:<9} pins={:<9} {:>12}",
            kind.name(),
            m.h.num_vertices(),
            m.h.num_pins(),
            BenchStats::fmt_time(s.median)
        );
        records.push(Record::new(
            "build_model",
            format!("amg-n{grid_n}-{}", kind.name()),
            1,
            s.median * 1e9,
        ));
    }
    let s = bench(1, 3, || fine_grained(&a, &p, true).unwrap());
    println!(
        "{:<16} (with V^nz)                    {:>12}",
        "fine-grained",
        BenchStats::fmt_time(s.median)
    );

    println!("\n== tile-product engine: PJRT vs reference ==");
    let tile = 8usize;
    let n = if smoke { 64 } else { 256 };
    let t2 = tile * tile;
    let mut rngf = Rng::new(8);
    let abuf: Vec<f32> = (0..n * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
    let bbuf: Vec<f32> = (0..n * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
    let mut reference = Engine::reference();
    let s = bench(1, 10, || reference.tile_products(tile, n, &abuf, &bbuf).unwrap());
    let flops = 2.0 * (n * tile * tile * tile) as f64;
    println!(
        "reference  {n} tiles of {tile}x{tile}: {:>12}  ({:.2} GFLOP/s)",
        BenchStats::fmt_time(s.median),
        flops / s.median / 1e9
    );
    records.push(Record::new("tile_products_ref", format!("{n}xT{tile}"), 1, s.median * 1e9));
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        match Engine::load("artifacts") {
            Ok(mut engine) => {
                let s = bench(2, 10, || engine.tile_products(tile, n, &abuf, &bbuf).unwrap());
                println!(
                    "pjrt       {n} tiles of {tile}x{tile}: {:>12}  ({:.2} GFLOP/s)",
                    BenchStats::fmt_time(s.median),
                    flops / s.median / 1e9
                );
                // larger tiles favor the compiled path
                for t in [16usize, 32] {
                    let t2 = t * t;
                    let ab: Vec<f32> = (0..64 * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
                    let bb: Vec<f32> = (0..64 * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
                    let sp = bench(2, 10, || engine.tile_products(t, 64, &ab, &bb).unwrap());
                    let sr = bench(1, 10, || reference.tile_products(t, 64, &ab, &bb).unwrap());
                    let fl = 2.0 * (64 * t * t * t) as f64;
                    println!(
                        "tile {t:>2}: pjrt {:>12} ({:.2} GFLOP/s) vs reference {:>12} ({:.2} GFLOP/s)",
                        BenchStats::fmt_time(sp.median),
                        fl / sp.median / 1e9,
                        BenchStats::fmt_time(sr.median),
                        fl / sr.median / 1e9
                    );
                }
            }
            Err(e) => println!("(PJRT path unavailable: {e})"),
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT side)");
    }

    if let Some(path) = json_path {
        write_json(&path, &records)?;
        println!("\nwrote {} records to {path}", records.len());
    }
    Ok(())
}
