//! SpGEMM substrate + kernel-path benches: Gustavson numeric multiply,
//! hypergraph construction, the sequential memory simulator, and the
//! PJRT tile-product engine vs. the pure-rust reference backend.

use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, fine_grained, ModelKind};
use spgemm_hp::runtime::Engine;
use spgemm_hp::sparse;
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;

fn main() {
    let mut rng = Rng::new(3);

    println!("== Gustavson SpGEMM ==");
    for (name, a, b) in [
        ("stencil27-n16 A*A", gen::stencil27(16), gen::stencil27(16)),
        (
            "rmat-s12 A*A",
            gen::rmat(&gen::RmatParams::social(12, 8.0), &mut rng).unwrap(),
            gen::rmat(&gen::RmatParams::social(12, 8.0), &mut Rng::new(3)).unwrap(),
        ),
    ] {
        let flops = sparse::spgemm_flops(&a, &b).unwrap();
        let s = bench(1, 5, || sparse::spgemm(&a, &b).unwrap());
        println!(
            "{name:<22} {:>12} mults  {:>12}  ({:.1} Mmult/s)",
            flops,
            BenchStats::fmt_time(s.median),
            flops as f64 / s.median / 1e6
        );
    }

    println!("\n== hypergraph model construction ==");
    let a = gen::stencil27(12);
    let p = gen::smoothed_aggregation_prolongator(&a, 12).unwrap();
    for kind in [ModelKind::FineGrained, ModelKind::RowWise, ModelKind::MonoC] {
        let s = bench(1, 5, || build_model(&a, &p, kind, false).unwrap());
        let m = build_model(&a, &p, kind, false).unwrap();
        println!(
            "{:<16} |V|={:<9} pins={:<9} {:>12}",
            kind.name(),
            m.h.num_vertices(),
            m.h.num_pins(),
            BenchStats::fmt_time(s.median)
        );
    }
    let s = bench(1, 3, || fine_grained(&a, &p, true).unwrap());
    println!("{:<16} (with V^nz)                    {:>12}", "fine-grained", BenchStats::fmt_time(s.median));

    println!("\n== tile-product engine: PJRT vs reference ==");
    let tile = 8usize;
    let n = 256usize;
    let t2 = tile * tile;
    let mut rngf = Rng::new(8);
    let abuf: Vec<f32> = (0..n * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
    let bbuf: Vec<f32> = (0..n * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
    let mut reference = Engine::reference();
    let s = bench(1, 10, || reference.tile_products(tile, n, &abuf, &bbuf).unwrap());
    let flops = 2.0 * (n * tile * tile * tile) as f64;
    println!(
        "reference  {n} tiles of {tile}x{tile}: {:>12}  ({:.2} GFLOP/s)",
        BenchStats::fmt_time(s.median),
        flops / s.median / 1e9
    );
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let mut engine = Engine::load("artifacts").expect("artifacts loadable");
        let s = bench(2, 10, || engine.tile_products(tile, n, &abuf, &bbuf).unwrap());
        println!(
            "pjrt       {n} tiles of {tile}x{tile}: {:>12}  ({:.2} GFLOP/s)",
            BenchStats::fmt_time(s.median),
            flops / s.median / 1e9
        );
        // larger tiles favor the compiled path
        for t in [16usize, 32] {
            let t2 = t * t;
            let ab: Vec<f32> = (0..64 * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
            let bb: Vec<f32> = (0..64 * t2).map(|_| rngf.range(-1.0, 1.0) as f32).collect();
            let sp = bench(2, 10, || engine.tile_products(t, 64, &ab, &bb).unwrap());
            let sr = bench(1, 10, || reference.tile_products(t, 64, &ab, &bb).unwrap());
            let fl = 2.0 * (64 * t * t * t) as f64;
            println!(
                "tile {t:>2}: pjrt {:>12} ({:.2} GFLOP/s) vs reference {:>12} ({:.2} GFLOP/s)",
                BenchStats::fmt_time(sp.median),
                fl / sp.median / 1e9,
                BenchStats::fmt_time(sr.median),
                fl / sr.median / 1e9
            );
        }
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT side)");
    }
}
