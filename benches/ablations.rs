//! Ablation bench for the partitioner's design choices (DESIGN.md
//! §Partitioner-design): multilevel coarsening vs. flat FM, number of
//! initial-partition starts, FM pass budget, and the ε balance knob —
//! each swept independently on a fixed workload so the contribution of
//! every component is visible.

use spgemm_hp::cost;
use spgemm_hp::gen;
use spgemm_hp::hypergraph::models::{build_model, ModelKind};
use spgemm_hp::partition::{partition, random_partition, PartitionerConfig};
use spgemm_hp::util::timer::{bench, BenchStats};
use spgemm_hp::util::Rng;

fn main() {
    let mut rng = Rng::new(9);
    let a = gen::rmat(&gen::RmatParams::social(9, 8.0), &mut rng).unwrap();
    let model = build_model(&a, &a, ModelKind::MonoC, false).unwrap();
    let p = 16;
    println!(
        "workload: monochrome-C model of rmat-s9 squaring — |V|={} pins={}, p={p}",
        model.h.num_vertices(),
        model.h.num_pins()
    );
    let base = PartitionerConfig { epsilon: 0.05, seed: 7, ..PartitionerConfig::new(p) };

    let eval = |cfg: &PartitionerConfig| {
        let t = std::time::Instant::now();
        let part = partition(&model.h, cfg).unwrap();
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let m = cost::evaluate(&model.h, &part, p).unwrap();
        (m.connectivity_volume, m.comm_max, m.comp_imbalance(), ms)
    };

    println!("\n-- baseline vs random --");
    let (vol, cm, imb, ms) = eval(&base);
    println!("multilevel:     volume={vol:<8} comm_max={cm:<8} imbal={imb:.3} ({ms:.0} ms)");
    let rp = random_partition(&model.h, p, 1);
    let mr = cost::evaluate(&model.h, &rp, p).unwrap();
    println!(
        "random:         volume={:<8} comm_max={:<8} imbal={:.3}",
        mr.connectivity_volume,
        mr.comm_max,
        mr.comp_imbalance()
    );

    println!("\n-- ablation: skip multilevel coarsening (flat FM from random) --");
    let flat = PartitionerConfig { coarse_to: usize::MAX, ..base.clone() };
    let (vol, cm, imb, ms) = eval(&flat);
    println!("flat FM:        volume={vol:<8} comm_max={cm:<8} imbal={imb:.3} ({ms:.0} ms)");

    println!("\n-- ablation: initial-partition starts --");
    for n_starts in [1usize, 4, 8, 16] {
        let cfg = PartitionerConfig { n_starts, ..base.clone() };
        let (vol, cm, _, ms) = eval(&cfg);
        println!("n_starts={n_starts:<3} volume={vol:<8} comm_max={cm:<8} ({ms:.0} ms)");
    }

    println!("\n-- ablation: FM pass budget --");
    for fm_passes in [0usize, 1, 2, 4, 8] {
        let cfg = PartitionerConfig { fm_passes, ..base.clone() };
        let (vol, cm, _, ms) = eval(&cfg);
        println!("fm_passes={fm_passes:<2} volume={vol:<8} comm_max={cm:<8} ({ms:.0} ms)");
    }

    println!("\n-- ablation: balance tolerance ε --");
    for eps in [0.01f64, 0.03, 0.10, 0.30] {
        let cfg = PartitionerConfig { epsilon: eps, ..base.clone() };
        let (vol, cm, imb, _) = eval(&cfg);
        println!("epsilon={eps:<5} volume={vol:<8} comm_max={cm:<8} imbal={imb:.3}");
    }

    println!("\n-- timing stability (median of 3) --");
    let s = bench(0, 3, || partition(&model.h, &base).unwrap());
    println!("partition time: {}", BenchStats::fmt_time(s.median));
}
